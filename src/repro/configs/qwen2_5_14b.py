"""Qwen2.5-14B — dense GQA with QKV bias.

[hf:Qwen/Qwen2.5-14B family] 48L, d_model=5120, 40H (GQA kv=8), d_ff=13824,
vocab=152064, qkv_bias=True.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    train_microbatches=8,
    source="hf:Qwen/Qwen2.5-0.5B (family card)",
)
