"""InternVL2-26B language backbone (InternLM2-20B) + stub ViT projector.

[arXiv:2404.16821] 48L, d_model=6144, 48H (GQA kv=8), d_ff=16384,
vocab=92553. Vision encoder (InternViT-6B) is a stub: input_specs supplies
(B, 256, 6144) projected patch embeddings prepended to the text sequence.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    n_stub_embeds=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    train_microbatches=8,
    source="arXiv:2404.16821 (InternVL2)",
)
