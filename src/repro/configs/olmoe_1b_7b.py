"""OLMoE-1B-7B — 64-expert top-8 MoE (1B active / 7B total).

[arXiv:2409.02060] 16L, d_model=2048, 16H (kv=16, MHA), d_ff=1024 (per
expert), vocab=50304, 64 experts top-8. 64 experts shard cleanly over the
16-way model axis => expert parallelism.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    experts_per_token=8,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    train_microbatches=8,
    source="arXiv:2409.02060 (OLMoE)",
)
