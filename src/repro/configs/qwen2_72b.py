"""Qwen2-72B — dense GQA with QKV bias.

[arXiv:2407.10671] 80L, d_model=8192, 64H (GQA kv=8), d_ff=29568,
vocab=152064, qkv_bias=True.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    train_microbatches=16,
    source="arXiv:2407.10671 (Qwen2)",
)
