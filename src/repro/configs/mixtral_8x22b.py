"""Mixtral-8x22B — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088] 56L, d_model=6144, 48H (GQA kv=8), d_ff=16384,
vocab=32768, 8 experts top-2, SWA window 4096. SWA makes long_500k decode
sub-quadratic (ring KV cache of window size).
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1e6,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    train_microbatches=8,
    source="arXiv:2401.04088 (Mixtral of Experts)",
)
