"""Llama-3.2-1B — small llama3 dense GQA.

[hf:meta-llama/Llama-3.2-1B] 16L, d_model=2048, 32H (GQA kv=8), d_ff=8192,
vocab=128256, tied embeddings.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=5e5,
    remat=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    train_microbatches=2,
    source="hf:meta-llama/Llama-3.2-1B",
)
