"""SeamlessM4T-large-v2 transformer backbone (speech enc + text dec).

[arXiv:2308.11596] 24L enc + 24L dec, d_model=1024, 16H (kv=16, MHA),
d_ff=8192, vocab=256206. Modality frontend (mel + conv) is a stub:
input_specs supplies (B, enc_seq_len, 1024) frame embeddings.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=48,           # 24 enc + 24 dec (accounting)
    n_enc_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    enc_seq_len=4096,      # stub audio frames
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    train_microbatches=2,
    source="arXiv:2308.11596 (SeamlessM4T v2)",
)
