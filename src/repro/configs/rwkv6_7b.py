"""RWKV6 "Finch" 7B — attention-free SSM with data-dependent decay.

[arXiv:2404.05892] 32L, d_model=4096, d_ff=14336, vocab=65536; head size 64
(=> 64 wkv heads). No attention => long_500k runs on constant-size state.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="rwkv",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # wkv heads = d_model / 64
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_lora_dim=64,
    ssm_chunk=32,        # wkv chunk length (chunked path)
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    train_microbatches=8,
    source="arXiv:2404.05892 (RWKV-6 Finch)",
)
