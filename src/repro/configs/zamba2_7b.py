"""Zamba2-7B — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242] 81L, d_model=3584, 32H (kv=32, MHA in the shared block),
d_ff=14336, vocab=32000, ssm_state=64. Shared attn applied every 6th layer.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    attn_every=6,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    train_microbatches=8,
    source="arXiv:2411.15242 (Zamba2)",
)
