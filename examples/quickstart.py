"""Quickstart: the paper's pipeline in ~40 lines.

Trains an autoencoder bank on three synthetic dataset analogues, builds an
ExpertMatcher, and routes held-out client samples (coarse + fine).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import MatcherConfig, build_matcher, train_bank
from repro.data import load_benchmark


def main():
    print("generating synthetic benchmark (mnist/har/reuters analogues)...")
    bench = load_benchmark(names=["mnist", "har", "reuters"],
                           n_per_dataset=1200, seed=0)
    names = list(bench)

    print("training one AE per dataset (paper recipe: Adam 1e-2, step decay)")
    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=30, batch_size=128)

    cents = [(bench[n]["server"][0], bench[n]["server"][1]) for n in names]
    matcher = build_matcher(aes, names, cents,
                            config=MatcherConfig(top_k=2))

    for client in ("client_a", "client_b"):
        accs = []
        for i, n in enumerate(names):
            x, _ = bench[n][client]
            pred = np.asarray(matcher.assign_coarse(jnp.asarray(x)))
            accs.append((pred == i).mean())
        print(f"{client}: coarse assignment accuracy per dataset "
              f"{[f'{a:.1%}' for a in accs]} (paper: ~99%)")

    # hierarchical route of a mixed batch
    x = np.concatenate([bench[n]["client_a"][0][:4] for n in names])
    routed = matcher.route(jnp.asarray(x))
    print("mixed batch -> experts:",
          [names[i] for i in np.asarray(routed["coarse"])[:, 0]])
    print("fine classes:", np.asarray(routed["fine"]).tolist())


if __name__ == "__main__":
    main()
