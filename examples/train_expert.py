"""Train an expert LM end-to-end on the synthetic token pipeline.

Runs a few hundred optimizer steps on a reduced llama-family expert
(CPU-sized; the same code path scales to the full configs on the
production mesh via repro.launch.train), then checkpoints and reloads.

  PYTHONPATH=src python examples/train_expert.py [--steps 200]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config
from repro.data import synthetic_token_stream
from repro.models import build_model
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt", default="/tmp/expert_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(
        n_layers=4, d_model=256, d_ff=512, vocab_size=1024)
    model = build_model(cfg)
    n_params = sum(int(np.prod(p.shape)) for p in
                   jax.tree_util.tree_leaves(model.init(
                       jax.random.PRNGKey(0))))
    print(f"training {cfg.name} ({n_params/1e6:.1f}M params) "
          f"for {args.steps} steps")

    tr = Trainer(model, lr=3e-3, total_steps=args.steps, microbatches=2)
    stream = synthetic_token_stream(cfg.vocab_size, args.seq, args.batch)
    t0 = time.time()
    hist = tr.fit(stream, steps=args.steps, log_every=25,
                  callback=lambda i, m: print(
                      f"  step {i:4d}  loss {float(m['loss']):.4f}  "
                      f"lr {float(m['lr']):.2e}"))
    print(f"done in {time.time()-t0:.1f}s; "
          f"loss {hist[0][1]:.3f} -> {hist[-1][1]:.3f}")

    save_pytree(tr.state["params"], args.ckpt)
    restored = load_pytree(args.ckpt)
    k0 = jax.tree_util.tree_leaves(restored)[0]
    print(f"checkpoint round-trip OK ({args.ckpt}, first leaf {k0.shape})")


if __name__ == "__main__":
    main()
