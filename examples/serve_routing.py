"""End-to-end serving driver (the paper's deployment scenario, Fig. 2).

Builds the full 6-dataset ExpertMatcher, registers three *different*
zoo architectures as expert backends (dense llama, attention-free RWKV6,
MoE mixtral — reduced variants), and serves batched client requests:
featurize -> coarse route -> fine route -> per-expert batched generation.

With ``--banked`` the placement planner banks each bankable
architecture's two experts into one vmapped dispatch group (optionally
sharded over a mesh ``expert`` axis when more than one device is
visible); capacity-dispatch MoE experts (mixtral) stay singleton shards
because their outputs depend on batch padding.

``--executor`` picks the dispatch executor: the default ``overlapped``
enqueues every shard's prefill and decode tick before blocking on
anything (sampled tokens stay on device; the host blocks once per wave
in the batched harvest), ``serial`` is the blocking per-tick reference.
Both produce identical tokens — the run prints the host-sync counter so
the difference is visible.

  PYTHONPATH=src python examples/serve_routing.py [--requests 48] \
      [--banked] [--executor {serial,overlapped}]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ExpertRegistry, build_matcher, train_bank
from repro.data import load_benchmark
from repro.launch.mesh import make_expert_mesh
from repro.models import build_model
from repro.serve import (ExpertEngine, Request, RoutedServer,
                         plan_placement)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--n-per-dataset", type=int, default=2000)
    ap.add_argument("--banked", action="store_true",
                    help="bank homogeneous experts via plan_placement")
    ap.add_argument("--executor", choices=("serial", "overlapped"),
                    default="overlapped",
                    help="dispatch executor (overlapped = async; serial "
                         "= blocking per-tick reference)")
    args = ap.parse_args()

    t0 = time.time()
    bench = load_benchmark(n_per_dataset=args.n_per_dataset, seed=0)
    names = list(bench)
    print(f"[{time.time()-t0:5.1f}s] datasets: {names}")

    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=40, batch_size=64)
    cents = [(bench[n]["server"][0], bench[n]["server"][1]) for n in names]
    matcher = build_matcher(aes, names, cents)
    print(f"[{time.time()-t0:5.1f}s] matcher bank trained (6 AEs)")

    # three heterogeneous expert backends, cycled across the 6 datasets
    backends = ["llama3.2-1b", "rwkv6-7b", "mixtral-8x22b"]
    registry = ExpertRegistry()
    for i, n in enumerate(names):
        arch = backends[i % len(backends)]
        cfg = get_config(arch).reduced(name=f"{arch}-expert-{n}")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(i))
        registry.add(n, ExpertEngine(model, params, max_len=96),
                     arch=arch)
    print(f"[{time.time()-t0:5.1f}s] {len(registry)} expert engines up "
          f"(families: dense, rwkv, moe)")

    plan = None
    if args.banked:
        plan = plan_placement(registry, mesh=make_expert_mesh())
        print(f"[{time.time()-t0:5.1f}s] placement "
              f"({len(jax.devices())} device(s)):")
        for line in plan.describe(registry.names).splitlines():
            print(f"    {line}")
    server = RoutedServer(matcher, registry, max_batch=8, placement=plan,
                          executor=args.executor)
    rng = np.random.default_rng(0)
    reqs, truth = [], []
    for uid in range(args.requests):
        n = names[rng.integers(len(names))]
        x, _ = bench[n]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[rng.integers(len(x))],
            prompt=rng.integers(0, 200, size=int(rng.integers(4, 24))),
            max_new_tokens=8))
        truth.append(n)

    t1 = time.time()
    resps = server.serve(reqs)
    dt = time.time() - t1
    correct = sum(r.expert == t for r, t in zip(resps, truth))
    print(f"[{time.time()-t0:5.1f}s] served {len(resps)} requests in "
          f"{dt:.2f}s ({len(resps)/dt:.1f} req/s on 1 CPU core)")
    print(f"routing accuracy: {correct}/{len(resps)} "
          f"({correct/len(resps):.1%})")
    for r in resps[:5]:
        print(f"  req {r.uid}: -> {r.expert} (fine class {r.fine_class}) "
              f"tokens {r.tokens.tolist()}")

    # continuous-batching internals: compile counts stay bucket-bounded
    st = server.stats
    print(f"scheduler: {st['scheduler']['batches']} micro-batches, "
          f"{st['router']['cache_hits']} route-cache hits, "
          f"executor={st['executor']}")
    for name, es in {**st["engines"], **st["banks"]}.items():
        print(f"  {name}: {es.prefill_calls} prefills, "
              f"{es.decode_steps} decode ticks, "
              f"{es.jit_cache_entries} compiled executables, "
              f"{es.host_blocks} host-blocking syncs")

    # second wave with repeated fingerprints rides the routing LRU and
    # the already-compiled bucket executables
    t2 = time.time()
    server.serve([Request(uid=10_000 + r.uid, features=reqs[i].features,
                          prompt=reqs[i].prompt,
                          max_new_tokens=reqs[i].max_new_tokens)
                  for i, r in enumerate(resps)])
    print(f"repeat wave: {len(resps)} reqs in {time.time()-t2:.2f}s "
          f"(route-cache hits now {server.stats['router']['cache_hits']})")


if __name__ == "__main__":
    main()
