"""End-to-end serving driver (the paper's deployment scenario, Fig. 2).

Builds the full 6-dataset ExpertMatcher, registers three *different*
zoo architectures as expert backends (dense llama, attention-free RWKV6,
MoE mixtral — reduced variants), and serves batched client requests:
featurize -> coarse route -> fine route -> per-expert batched generation.

With ``--banked`` the placement planner banks each bankable
architecture's two experts into one vmapped dispatch group (optionally
sharded over a mesh ``expert`` axis when more than one device is
visible); capacity-dispatch MoE experts (mixtral) stay singleton shards
because their outputs depend on batch padding.

``--executor`` picks the dispatch executor: the default ``overlapped``
enqueues every shard's prefill and decode tick before blocking on
anything (sampled tokens stay on device; the host blocks once per wave
in the batched harvest), ``serial`` is the blocking per-tick reference.
Both produce identical tokens — the run prints the host-sync counter so
the difference is visible.

With ``--hub`` the experts are served through an ``ExpertHub`` with
only ``--resident`` device slots (fewer than the expert count): the
matcher routes exactly as before, but a request landing on a
non-resident expert *parks* (the ``NotResident`` outcome) while the
hub stages the expert's checkpoint in the background and commits it
into a slot — the demo walks one such cold-start request through
park → load → serve and prints the ``HubStats`` ledger.

With ``--long-prompt`` the demo instead drives whale prompts through
the chunked suffix-prefill path: cohorts of long prompts share a
32-token head, the chunked server adopts the cached head pages and
computes only the uncached suffix chunks (budgeted per scheduler step,
so short requests keep decoding while a whale prefills), and the run
prints the prefill-tokens-computed savings against a storage-only
paged baseline serving the identical stream.

  PYTHONPATH=src python examples/serve_routing.py [--requests 48] \
      [--banked] [--executor {serial,overlapped}] \
      [--hub --resident 2] [--long-prompt]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ExpertRegistry, build_matcher, train_bank
from repro.data import load_benchmark
from repro.launch.mesh import make_expert_mesh
from repro.models import build_model
from repro.serve import (ExpertEngine, ExpertHub, Request, RoutedServer,
                         plan_placement)


def hub_cold_start_demo(server, hub, bench, names, t0):
    """Walk one request to a *non-resident* expert through the full
    lifecycle: park (NotResident backpressure) → stage (checkpoint →
    host) → commit (host → device slot) → serve."""
    sched = server.scheduler
    cold = [e for e in range(len(names)) if hub.slot_of(e) is None]
    if not cold:
        print("    (every expert is resident; raise the expert count "
              "or lower --resident to see a cold start)")
        return
    # pick a cold expert AND a client feature the matcher really routes
    # to it (coarse routing is ~90% accurate; a misroute would demo a
    # different expert's path)
    e, feat = cold[0], None
    for cand_e in cold:
        x, _ = bench[names[cand_e]]["client_a"]
        for cand in x[:32]:
            if int(server.router.route(cand[None]).coarse[0, 0]) == cand_e:
                e, feat = cand_e, cand
                break
        if feat is not None:
            break
    if feat is None:
        x, _ = bench[names[e]]["client_a"]
        feat = x[0]
    name = hub.catalog[e].name
    print(f"[{time.time()-t0:5.1f}s] cold-start demo: expert {name!r} "
          f"is {hub.catalog[e].state} (resident: "
          f"{[hub.catalog[r].name for r in hub.resident_experts]})")
    server.submit([Request(uid=999_000, features=feat,
                           prompt=np.arange(12, dtype=np.int32),
                           max_new_tokens=6)])
    resp, step, seen = None, 0, []
    while resp is None:
        got = server.step()
        step += 1
        state = hub.catalog[e].state
        if not seen or seen[-1][1] != state:
            seen.append((step, state))
        for r in got:
            if r.uid == 999_000:
                resp = r
    for step_no, state in seen:
        print(f"    step {step_no}: {name!r} {state}")
    stalls = sched.stats.resident_stalls
    print(f"[{time.time()-t0:5.1f}s] served by {resp.expert!r} after "
          f"{step} steps ({stalls} resident-miss stalls so far); "
          f"tokens {resp.tokens.tolist()}")
    print(f"    {hub.stats!r}")


def long_prompt_demo(matcher, bench, names, t0, n_requests=36):
    """Whale prompts through the chunked suffix-prefill path: two
    cohorts of long prompts share a 32-token head, so after a priming
    wave the chunked server adopts the cached head pages and computes
    only the uncached suffix chunk of each whale, while the storage-only
    paged baseline recomputes every whale in full."""
    cfg = get_config("llama3.2-1b").reduced(name="lp-expert")
    model = build_model(cfg)
    params = {n: model.init(jax.random.PRNGKey(i))
              for i, n in enumerate(names)}

    def make_server(chunked):
        registry = ExpertRegistry()
        for n in names:
            registry.add(n, ExpertEngine(
                model, params[n], max_len=128, kv_layout="paged",
                chunk_len=32 if chunked else None))
        return RoutedServer(matcher, registry, max_batch=8,
                            prefill_tokens_per_step=32 if chunked else 0)

    rng = np.random.default_rng(7)
    cohorts = names[::3]  # two whale cohorts, one shared head each
    heads = {n: rng.integers(0, 200, size=32) for n in cohorts}

    def whale(uid, n):
        x, _ = bench[n]["client_a"]
        tail = rng.integers(0, 200, size=int(rng.integers(20, 29)))
        return Request(uid=uid, features=x[int(rng.integers(len(x)))],
                       prompt=np.concatenate([heads[n], tail]),
                       max_new_tokens=6)

    def short(uid):
        n = names[int(rng.integers(len(names)))]
        x, _ = bench[n]["client_a"]
        return Request(uid=uid, features=x[int(rng.integers(len(x)))],
                       prompt=rng.integers(0, 200,
                                           size=int(rng.integers(4, 20))),
                       max_new_tokens=6)

    prime = [whale(900 + i, n) for i, n in enumerate(cohorts)]
    stream = [whale(uid, cohorts[(uid // 3) % len(cohorts)])
              if uid % 3 == 0 else short(uid)
              for uid in range(n_requests)]
    n_whales = sum(1 for r in stream if len(r.prompt) > 32)
    print(f"[{time.time()-t0:5.1f}s] long-prompt demo: "
          f"{len(prime)} priming whales, then {len(stream)} requests "
          f"({n_whales} cohort whales interleaved with short traffic)")

    results = {}
    for label, chunked in (("chunked+suffix", True), ("storage-only", False)):
        srv = make_server(chunked)
        toks = {}
        for wave in (prime, stream):
            for r in srv.serve(list(wave)):
                toks[r.uid] = r.tokens.tolist()
        es = list(srv.stats["engines"].values())
        computed = sum(e.prefill_tokens_computed for e in es)
        submitted = sum(e.prefill_tokens_submitted for e in es)
        results[label] = (computed, toks)
        print(f"[{time.time()-t0:5.1f}s] {label:>14}: computed {computed} "
              f"prompt tokens ({submitted} submitted before padding)")
    (c1, t1), (c0, t0_) = results["chunked+suffix"], results["storage-only"]
    assert t1 == t0_, "token divergence between chunked and storage-only"
    print(f"    suffix prefill over cached cohort heads computed "
          f"{c0 - c1} fewer prompt tokens ({1 - c1 / max(c0, 1):.0%} less "
          f"than storage-only paged); tokens identical across both servers")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--n-per-dataset", type=int, default=2000)
    ap.add_argument("--banked", action="store_true",
                    help="bank homogeneous experts via plan_placement")
    ap.add_argument("--executor", choices=("serial", "overlapped"),
                    default="overlapped",
                    help="dispatch executor (overlapped = async; serial "
                         "= blocking per-tick reference)")
    ap.add_argument("--hub", action="store_true",
                    help="serve through an ExpertHub with --resident "
                         "device slots: non-resident experts cold-start "
                         "on demand (park -> load -> serve)")
    ap.add_argument("--resident", type=int, default=2,
                    help="hub device slots (with --hub; fewer than the "
                         "6 experts so evictions actually happen)")
    ap.add_argument("--long-prompt", action="store_true",
                    help="whale-prompt demo: chunked suffix prefill "
                         "over cached cohort heads vs storage-only "
                         "paged, printing prefill-tokens-computed "
                         "savings")
    args = ap.parse_args()
    if args.hub and args.banked:
        ap.error("--hub and --banked are exclusive (the hub owns its "
                 "own slot bank)")
    if args.long_prompt and (args.hub or args.banked):
        ap.error("--long-prompt is a standalone demo (no --hub/--banked)")

    t0 = time.time()
    bench = load_benchmark(n_per_dataset=args.n_per_dataset, seed=0)
    names = list(bench)
    print(f"[{time.time()-t0:5.1f}s] datasets: {names}")

    aes, _ = train_bank([(n, bench[n]["server"][0]) for n in names],
                        epochs=40, batch_size=64)
    cents = [(bench[n]["server"][0], bench[n]["server"][1]) for n in names]
    matcher = build_matcher(aes, names, cents)
    print(f"[{time.time()-t0:5.1f}s] matcher bank trained (6 AEs)")

    if args.long_prompt:
        long_prompt_demo(matcher, bench, names, t0,
                         n_requests=args.requests)
        return

    hub = None
    if args.hub:
        # one homogeneous architecture: hub slots are shape-compatible
        # by construction (equal ExpertSpec), so any expert can land in
        # any slot without recompiling
        cfg = get_config("llama3.2-1b").reduced(name="llama-expert")
        model = build_model(cfg)
        hub = ExpertHub(model, n_slots=args.resident, max_len=96)
        for i, n in enumerate(names):
            hub.add_expert(n, model.init(jax.random.PRNGKey(i)))
        registry = hub.build_registry()
        print(f"[{time.time()-t0:5.1f}s] hub up: {len(registry)} "
              f"experts catalogued, {args.resident} device slots")
    else:
        # three heterogeneous expert backends, cycled over the datasets
        backends = ["llama3.2-1b", "rwkv6-7b", "mixtral-8x22b"]
        registry = ExpertRegistry()
        for i, n in enumerate(names):
            arch = backends[i % len(backends)]
            cfg = get_config(arch).reduced(name=f"{arch}-expert-{n}")
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(i))
            registry.add(n, ExpertEngine(model, params, max_len=96),
                         arch=arch)
        print(f"[{time.time()-t0:5.1f}s] {len(registry)} expert engines "
              f"up (families: dense, rwkv, moe)")

    plan = None
    if args.banked:
        plan = plan_placement(registry, mesh=make_expert_mesh())
        print(f"[{time.time()-t0:5.1f}s] placement "
              f"({len(jax.devices())} device(s)):")
        for line in plan.describe(registry.names).splitlines():
            print(f"    {line}")
    server = RoutedServer(matcher, registry, max_batch=8, placement=plan,
                          executor=args.executor, hub=hub)
    rng = np.random.default_rng(0)
    reqs, truth = [], []
    for uid in range(args.requests):
        n = names[rng.integers(len(names))]
        x, _ = bench[n]["client_a"]
        reqs.append(Request(
            uid=uid, features=x[rng.integers(len(x))],
            prompt=rng.integers(0, 200, size=int(rng.integers(4, 24))),
            max_new_tokens=8))
        truth.append(n)

    t1 = time.time()
    resps = server.serve(reqs)
    dt = time.time() - t1
    correct = sum(r.expert == t for r, t in zip(resps, truth))
    print(f"[{time.time()-t0:5.1f}s] served {len(resps)} requests in "
          f"{dt:.2f}s ({len(resps)/dt:.1f} req/s on 1 CPU core)")
    print(f"routing accuracy: {correct}/{len(resps)} "
          f"({correct/len(resps):.1%})")
    for r in resps[:5]:
        print(f"  req {r.uid}: -> {r.expert} (fine class {r.fine_class}) "
              f"tokens {r.tokens.tolist()}")

    # continuous-batching internals: compile counts stay bucket-bounded
    st = server.stats
    print(f"scheduler: {st['scheduler'].batches} micro-batches, "
          f"{st['router']['cache_hits']} route-cache hits, "
          f"executor={st['executor']}")
    for name, es in {**st["engines"], **st["banks"]}.items():
        print(f"  {name}: {es.prefill_calls} prefills, "
              f"{es.decode_steps} decode ticks, "
              f"{es.jit_cache_entries} compiled executables, "
              f"{es.host_blocks} host-blocking syncs")

    if args.hub:
        hub_cold_start_demo(server, hub, bench, names, t0)

    # second wave with repeated fingerprints rides the routing LRU and
    # the already-compiled bucket executables
    t2 = time.time()
    server.serve([Request(uid=10_000 + r.uid, features=reqs[i].features,
                          prompt=reqs[i].prompt,
                          max_new_tokens=reqs[i].max_new_tokens)
                  for i, r in enumerate(resps)])
    print(f"repeat wave: {len(resps)} reqs in {time.time()-t2:.2f}s "
          f"(route-cache hits now {server.stats['router']['cache_hits']})")


if __name__ == "__main__":
    main()
